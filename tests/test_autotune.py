"""Autotuned execution geometry + persistent tuning/plan cache tests
(``repro.sparse_api.autotune``).

Contract under test:

* tuning keys bucket like the executable cache (HFlex): contents never
  enter the key, geometry is bucketed, streaming embeds a budget class;
* the TuningDB round-trips records through a schema-versioned JSON file
  (atomic writes, file lock, read-merge on store), shrugs off corrupt or
  schema-mismatched files, and merges across instances/processes;
* ``plan(..., autotune=)`` applies stored decisions ("cached") or
  measures + stores on a miss ("measure"), and every accepted candidate
  is **bit-identical** to the default resolution — the tuner may only
  re-route among result-identical implementations;
* plan executables persist to disk and a cold plan cache (or a fresh
  process) reloads them instead of re-tracing;
* the engine/scheduler surface the story as counters: plan-cache
  hits/misses/evictions, tuned dispatches, TuningDB traffic, cold vs
  warm plan-build seconds.
"""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

import repro.sparse_api as sp
from repro.core.sparse import power_law_sparse
from repro.sparse_api import autotune as at


@pytest.fixture()
def tune_dir(tmp_path, monkeypatch):
    d = tmp_path / "tunedb"
    d.mkdir()
    monkeypatch.setenv("SEXTANS_TUNE_DIR", str(d))
    return str(d)


def _packed(m=200, k=320, seed=1, tm=64, k0=64):
    a = power_law_sparse(m, k, 5, seed=seed)
    return sp.from_sparse_matrix(a, tm=tm, k0=k0, chunk=8, bucket=True)


class TestTuneKey:
    def test_contents_excluded_geometry_bucketed(self):
        """Two matrices in the same geometry bucket share a tuning key —
        the HFlex property carried into the tuner."""
        k1 = at.tune_key(_packed(seed=1), 8)
        k2 = at.tune_key(_packed(seed=9), 8)
        assert k1 == k2

    def test_n_buckets_pow2(self):
        A = _packed()
        assert at.tune_key(A, 9) == at.tune_key(A, 16)
        assert at.tune_key(A, 8) != at.tune_key(A, 16)

    def test_stream_tier_and_budget_class(self):
        A = _packed()
        res = at.tune_key(A, 8)
        srm = at.tune_key(A, 8, stream=True)
        assert res != srm and "stream" in srm
        # budgets in the same pow2 class share a key; different classes don't
        b1 = at.tune_key(A, 8, stream=True, device_bytes=1 << 20)
        b2 = at.tune_key(A, 8, stream=True, device_bytes=(1 << 20) + 5000)
        b3 = at.tune_key(A, 8, stream=True, device_bytes=1 << 22)
        assert b1 == b2 != b3

    def test_group_and_dtype_enter_key(self):
        A = _packed()
        assert at.tune_key(A, 8) != at.tune_key(A, 8, group=4)
        assert at.tune_key(A, 8) != at.tune_key(A, 8, dtype=jnp.float64)

    def test_schema_prefix(self):
        assert at.tune_key(_packed(), 8).startswith(f"v{at.TUNE_SCHEMA}|")


class TestTuningDB:
    def test_roundtrip_and_persistence(self, tune_dir):
        db = at.TuningDB(tune_dir)
        rec = {"schema": at.TUNE_SCHEMA, "backend": "jnp", "us": 12.5}
        db.store("k1", rec)
        assert db.lookup("k1")["backend"] == "jnp"
        # a FRESH instance reads the same file
        db2 = at.TuningDB(tune_dir)
        assert db2.lookup("k1")["us"] == 12.5
        assert len(db2) == 1

    def test_cross_instance_merge(self, tune_dir):
        """store() read-merges under the file lock: two instances writing
        different keys both survive (last-writer-wins per key, not per
        file)."""
        db1 = at.TuningDB(tune_dir)
        db2 = at.TuningDB(tune_dir)
        db1.store("a", {"schema": at.TUNE_SCHEMA, "v": 1})
        db2.store("b", {"schema": at.TUNE_SCHEMA, "v": 2})
        db3 = at.TuningDB(tune_dir)
        assert db3.lookup("a") and db3.lookup("b")

    def test_corrupt_file_tolerated(self, tune_dir):
        db = at.TuningDB(tune_dir)
        db.store("k", {"schema": at.TUNE_SCHEMA, "v": 1})
        with open(db.file, "w") as f:
            f.write("{not json")
        fresh = at.TuningDB(tune_dir)
        assert fresh.lookup("k") is None          # degraded, not raised
        fresh.store("k2", {"schema": at.TUNE_SCHEMA, "v": 2})
        assert fresh.lookup("k2")

    def test_schema_mismatch_discarded(self, tune_dir):
        db = at.TuningDB(tune_dir)
        db.store("k", {"schema": at.TUNE_SCHEMA, "v": 1})
        with open(db.file) as f:
            payload = json.load(f)
        payload["schema"] = at.TUNE_SCHEMA + 999
        with open(db.file, "w") as f:
            json.dump(payload, f)
        assert at.TuningDB(tune_dir).lookup("k") is None

    def test_no_dir_is_memory_only(self, monkeypatch):
        monkeypatch.delenv("SEXTANS_TUNE_DIR", raising=False)
        db = at.TuningDB(None)
        db.store("k", {"schema": at.TUNE_SCHEMA, "v": 1})
        assert db.lookup("k")["v"] == 1
        assert db.file is None


class TestResolveMode:
    def test_modes(self, monkeypatch):
        assert at.resolve_mode("measure") == "measure"
        monkeypatch.delenv("SEXTANS_AUTOTUNE", raising=False)
        assert at.resolve_mode(None) == "off"
        monkeypatch.setenv("SEXTANS_AUTOTUNE", "cached")
        assert at.resolve_mode(None) == "cached"

    def test_bogus_mode_raises(self, tune_dir):
        with pytest.raises(ValueError):
            sp.plan(_packed(), 8, autotune="bogus")


class TestTunedPlans:
    def test_measure_then_cached_bit_identical(self, tune_dir):
        """measure-mode tunes + stores; cached-mode applies the record;
        both run bit-identically to the default resolution."""
        rng = np.random.default_rng(0)
        A = _packed()
        b = jnp.asarray(rng.standard_normal((A.shape[1], 8)), jnp.float32)
        y_ref = np.asarray(sp.plan(A, 8).run(b))

        s0 = dict(at.TUNE_STATS)
        P = sp.plan(A, 8, autotune="measure")
        assert P.tuned
        assert at.TUNE_STATS["db_misses"] > s0["db_misses"]
        assert at.TUNE_STATS["measured"] > s0["measured"]
        np.testing.assert_array_equal(np.asarray(P.run(b)), y_ref)

        sp.clear_plan_cache()
        s1 = dict(at.TUNE_STATS)
        P2 = sp.plan(A, 8, autotune="cached")
        assert P2.tuned
        assert at.TUNE_STATS["db_hits"] > s1["db_hits"]
        assert at.TUNE_STATS["measured"] == s1["measured"]  # no re-measure
        np.testing.assert_array_equal(np.asarray(P2.run(b)), y_ref)

    def test_cached_without_record_is_default(self, tune_dir):
        P = sp.plan(_packed(seed=17, m=250), 8, autotune="cached")
        assert not P.tuned                        # empty DB: heuristics

    def test_explicit_backend_not_overridden(self, tune_dir):
        """Tuning only touches knobs the caller left open."""
        P = sp.plan(_packed(), 8, backend="jnp", autotune="measure")
        assert P.backend == "jnp" and not P.tuned

    def test_streaming_tune_bit_identical_and_coarser(self, tune_dir):
        """Forced streaming with no budget: the heuristic takes
        window_chunk=1; the tuner may pick any feasible chunking but the
        result must stay bit-identical."""
        rng = np.random.default_rng(0)
        A = _packed(m=256, k=512, k0=64)
        b = rng.standard_normal((512, 8)).astype(np.float32)
        S_def = sp.plan(A, 8, backend="jnp", stream=True)
        assert S_def.window_chunk == 1            # the heuristic floor
        S_tun = sp.plan(A, 8, backend="jnp", stream=True, autotune="measure")
        assert S_tun.tuned
        assert S_tun.window_chunk >= 1
        np.testing.assert_array_equal(np.asarray(S_tun.run(b)),
                                      np.asarray(S_def.run(b)))

    def test_tune_plan_records_decision(self, tune_dir):
        A = _packed()
        res = at.tune_plan(A, 8, repeats=2, measure_top=2)
        assert res.record["schema"] == at.TUNE_SCHEMA
        assert res.record["backend"] in sp.list_backends()
        db = at.get_db()
        assert db.lookup(res.key)["backend"] == res.record["backend"]
        # the stored decision beat or matched the default measurement
        assert res.record["us"] <= res.record["default_us"] * 1.5


class TestExecPersistence:
    def test_roundtrip_after_cache_clear(self, tune_dir):
        """Persisted executables reload after clear_plan_cache(): the
        second build is a persist hit, not a recompile."""
        rng = np.random.default_rng(0)
        A = _packed(seed=23)
        b = jnp.asarray(rng.standard_normal((A.shape[1], 8)), jnp.float32)
        sp.clear_plan_cache()                     # force a compile HERE so
        stores0 = sp.PLAN_STATS["exec_persist_stores"]  # it persists to
        P = sp.plan(A, 8, backend="jnp")          # THIS test's tune dir
        y = np.asarray(P.run(b))
        assert sp.PLAN_STATS["exec_persist_stores"] > stores0
        sp.clear_plan_cache()
        hits0 = sp.PLAN_STATS["exec_persist_hits"]
        P2 = sp.plan(A, 8, backend="jnp")
        assert sp.PLAN_STATS["exec_persist_hits"] > hits0
        np.testing.assert_array_equal(np.asarray(P2.run(b)), y)

    def test_exec_files_on_disk(self, tune_dir):
        A = _packed(seed=29)
        sp.clear_plan_cache()                     # compile under this dir
        sp.plan(A, 8, backend="jnp")
        execs = os.path.join(tune_dir, "execs")
        assert os.path.isdir(execs) and os.listdir(execs)

    def test_save_load_roundtrip_api(self, tune_dir):
        import jax

        compiled = jax.jit(lambda x: x * 2).lower(
            jnp.zeros((4,), jnp.float32)).compile()
        key = ("unit", "roundtrip")
        assert at.save_exec(key, compiled)
        loaded = at.load_exec(key)
        assert loaded is not None
        np.testing.assert_array_equal(
            np.asarray(loaded(jnp.ones((4,), jnp.float32))),
            np.full((4,), 2.0, np.float32))

    def test_load_miss_returns_none(self, tune_dir):
        assert at.load_exec(("never", "stored")) is None


class TestEngineCounters:
    def test_plan_cache_hits_misses_and_build_split(self, tune_dir):
        from repro.core.engine import SextansEngine

        rng = np.random.default_rng(0)
        a = power_law_sparse(128, 160, 5, seed=3)
        eng = SextansEngine(tm=64, k0=64, chunk=8, impl="jnp")
        t = eng.pack(a)
        b = jnp.asarray(rng.standard_normal((160, 8)), jnp.float32)
        eng.spmm(t, b)
        eng.spmm(t, b)
        st = eng.stats_snapshot()
        assert st.plan_cache_misses == 1
        assert st.plan_cache_hits == 1
        assert st.plan_cache_hit_rate == 0.5
        assert st.plan_builds_cold + st.plan_builds_warm == 1
        assert st.plan_build_cold_s + st.plan_build_warm_s > 0

    def test_eviction_counter(self, tune_dir):
        from repro.core.engine import SextansEngine

        rng = np.random.default_rng(0)
        eng = SextansEngine(tm=64, k0=64, chunk=8, impl="jnp")
        eng.PLAN_CACHE_CAP = 2                    # instance override
        t = eng.pack(power_law_sparse(128, 160, 5, seed=3))
        for n in (8, 16, 24, 32):
            eng.spmm(t, jnp.asarray(
                rng.standard_normal((160, n)), jnp.float32))
        st = eng.stats_snapshot()
        assert st.plan_cache_evictions >= 2
        assert st.plan_cache_misses == 4

    def test_tuned_dispatches_and_db_traffic(self, tune_dir):
        from repro.core.engine import SextansEngine

        rng = np.random.default_rng(0)
        a = power_law_sparse(128, 160, 5, seed=3)
        b = jnp.asarray(rng.standard_normal((160, 8)), jnp.float32)
        eng = SextansEngine(tm=64, k0=64, chunk=8, impl="auto",
                            autotune="measure")
        t = eng.pack(a)
        y1 = eng.spmm(t, b)
        st = eng.stats_snapshot()
        assert st.tuned_dispatches == 1
        assert st.tune_db_misses == 1             # cold: measured + stored
        assert st.plan_builds_cold == 1
        # second engine, same DB: pure hit, warm-or-cold build but no
        # re-measure, same bits
        eng2 = SextansEngine(tm=64, k0=64, chunk=8, impl="auto",
                             autotune="measure")
        y2 = eng2.spmm(eng2.pack(a), b)
        st2 = eng2.stats_snapshot()
        assert st2.tune_db_hits == 1 and st2.tune_db_misses == 0
        np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))

    def test_engine_off_mode_never_touches_db(self, tune_dir):
        from repro.core.engine import SextansEngine

        rng = np.random.default_rng(0)
        eng = SextansEngine(tm=64, k0=64, chunk=8, impl="auto")
        t = eng.pack(power_law_sparse(128, 160, 5, seed=3))
        eng.spmm(t, jnp.asarray(rng.standard_normal((160, 8)), jnp.float32))
        st = eng.stats_snapshot()
        assert st.tuned_dispatches == 0
        assert st.tune_db_hits == 0 and st.tune_db_misses == 0


class TestSchedulerSurface:
    def test_last_flush_and_cumulative_keys(self, tune_dir):
        from repro.core.engine import SextansEngine
        from repro.launch.serve import SpmmRequest, SpmmScheduler

        rng = np.random.default_rng(0)
        eng = SextansEngine(tm=64, k0=64, chunk=8, impl="auto")
        sched = SpmmScheduler(eng, autotune="measure")
        assert eng.autotune == "measure"          # mode threaded through
        for i in range(3):
            sched.submit(SpmmRequest(
                a=power_law_sparse(128, 160, 5, seed=i),
                b=rng.standard_normal((160, 8)).astype(np.float32)))
        sched.flush()
        for key in ("tuned_dispatches", "tune_db_hits", "tune_db_misses",
                    "plan_build_cold_s", "plan_build_warm_s"):
            assert key in sched.stats, key
            assert key in sched.stats["last_flush"], key
        lf = sched.stats["last_flush"]
        assert lf["tuned_dispatches"] > 0
        assert lf["tune_db_hits"] + lf["tune_db_misses"] > 0

    def test_serve_pool_warm_run_all_hits(self, tune_dir):
        from repro.core.engine import SextansEngine
        from repro.launch.serve import SpmmRequest, serve_spmm_requests

        rng = np.random.default_rng(0)
        reqs = [SpmmRequest(
            a=power_law_sparse(128, 160, 5, seed=i),
            b=rng.standard_normal((160, 8)).astype(np.float32))
            for i in range(4)]

        def run():
            eng = SextansEngine(tm=64, k0=64, chunk=8, impl="auto",
                                autotune="measure")
            return serve_spmm_requests(reqs, eng)

        outs1, stats1 = run()
        outs2, stats2 = run()
        assert stats2["tune_db_hits"] > 0
        assert stats2["tune_db_misses"] == 0
        assert stats2["tuned_dispatches"] > 0
        assert "plan_cache_hits" in stats2 and "plan_cache_misses" in stats2
        for a, b in zip(outs1, outs2):
            np.testing.assert_array_equal(a, b)


class TestSkinnyThresholdTuning:
    def test_tune_and_apply(self, tune_dir):
        import repro.sparse_api.backends as _bk

        try:
            thr = at.tune_skinny_threshold(_packed(), widths=[1, 4],
                                           repeats=1, apply=True)
            assert thr >= 0
            assert sp.skinny_n_max() == thr
            rec = at.get_db().lookup(at.skinny_key())
            assert rec["skinny_n_max"] == thr
        finally:
            _bk.set_skinny_n_max(None)

    def test_apply_from_db_respects_env(self, tune_dir, monkeypatch):
        import repro.sparse_api.backends as _bk

        db = at.get_db()
        db.store(at.skinny_key(), {"schema": at.TUNE_SCHEMA,
                                   "skinny_n_max": 3})
        monkeypatch.setenv("SEXTANS_SKINNY_N_MAX", "12")
        try:
            assert at.apply_skinny_from_db(db) is None   # env wins
            assert sp.skinny_n_max() == 12
        finally:
            _bk.set_skinny_n_max(None)


class TestCompareSnapshots:
    def test_regression_detection(self, tmp_path):
        run = pytest.importorskip(
            "benchmarks.run",
            reason="benchmarks package importable from repo root only")
        old = {"schema": 1, "rows": [
            {"name": "a", "us": 100.0, "derived": ""},
            {"name": "b", "us": 100.0, "derived": ""},
            {"name": "gone", "us": 1.0, "derived": ""}]}
        new = {"schema": 1, "rows": [
            {"name": "a", "us": 110.0, "derived": ""},     # within tolerance
            {"name": "b", "us": 200.0, "derived": ""},     # regression
            {"name": "added", "us": 1.0, "derived": ""}]}
        po, pn = tmp_path / "old.json", tmp_path / "new.json"
        po.write_text(json.dumps(old))
        pn.write_text(json.dumps(new))
        assert run.compare_snapshots(str(po), str(pn), tolerance=1.25) == 1
        assert run.compare_snapshots(str(po), str(pn), tolerance=3.0) == 0
