"""Perf-lever equivalence tests: every §Perf optimization must be
numerically identical (or within dtype tolerance) to the baseline."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import smoke_config
from repro.models import model as M
from repro.models.layers import _chunked_attention


@pytest.fixture
def qkv(rng):
    B, S, H, HKV, hd = 2, 64, 4, 2, 16
    return (jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32),
            jnp.asarray(rng.standard_normal((B, S, HKV, hd)), jnp.float32),
            jnp.asarray(rng.standard_normal((B, S, HKV, hd)), jnp.float32))


@pytest.mark.parametrize("window", [None, 24, 8])
def test_pairlist_attention_exact(qkv, window):
    q, k, v = qkv
    base = _chunked_attention(q, k, v, 0, True, window, 16, 16,
                              skip_masked_blocks=False)
    fast = _chunked_attention(q, k, v, 0, True, window, 16, 16,
                              skip_masked_blocks=True)
    np.testing.assert_allclose(np.asarray(base), np.asarray(fast),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("arch", ["llama3.2-1b", "hymba-1.5b"])
def test_model_with_skip_blocks_matches(arch, rng):
    cfg = smoke_config(arch)
    cfg2 = dataclasses.replace(cfg, attn_skip_masked_blocks=True)
    params = M.init_params(cfg, 0)
    batch = {"tokens": jnp.asarray(rng.integers(0, 512, (2, 32)), jnp.int32)}
    l1 = M.forward(params, cfg, batch, remat=False)
    l2 = M.forward(params, cfg2, batch, remat=False)
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32),
                               rtol=1e-4, atol=1e-4)


def test_remat_policy_dots_same_loss_and_grads(rng):
    cfg = smoke_config("qwen2-0.5b")
    cfg2 = dataclasses.replace(cfg, remat_policy="dots")
    params = M.init_params(cfg, 0)
    batch = {"tokens": jnp.asarray(rng.integers(0, 512, (2, 32)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, 512, (2, 32)), jnp.int32)}
    l1, g1 = jax.value_and_grad(lambda p: M.loss_fn(p, cfg, batch))(params)
    l2, g2 = jax.value_and_grad(lambda p: M.loss_fn(p, cfg2, batch))(params)
    assert abs(float(l1) - float(l2)) < 1e-6
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_moe_group_size_equivalent(rng):
    cfg = smoke_config("qwen3-moe-235b-a22b")
    params = M.init_params(cfg, 0)
    batch = {"tokens": jnp.asarray(rng.integers(0, 512, (4, 32)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, 512, (4, 32)), jnp.int32)}
    losses = []
    for gs in (512, 128, 64):
        cfg_g = dataclasses.replace(cfg, moe_group_size=gs)
        losses.append(float(M.loss_fn(params, cfg_g, batch)))
    # smaller groups change capacity-dropping boundaries marginally; at
    # smoke scale (high capacity) results must agree closely
    assert max(losses) - min(losses) < 5e-3, losses


def test_embed_d_shard_same_loss(rng):
    from repro.distributed.sharding import param_specs, tree_named, axis_map_for
    from repro.launch.mesh import make_mesh_for
    from repro.models.layers import mesh_context

    cfg = smoke_config("qwen3-moe-235b-a22b")   # untied embeddings
    params = M.init_params(cfg, 0)
    batch = {"tokens": jnp.asarray(rng.integers(0, 512, (8, 16)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, 512, (8, 16)), jnp.int32)}
    ref = float(M.loss_fn(params, cfg, batch))

    mesh = make_mesh_for(8, model_parallel=2)
    pshape = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params)
    for dshard in (False, True):
        shard = tree_named(mesh, param_specs(pshape, mesh, embed_d_shard=dshard))
        sp = jax.device_put(params, shard)

        def lossf(p):
            with mesh_context(mesh, axis_map_for(mesh)):
                return M.loss_fn(p, cfg, batch)

        got = float(jax.jit(lossf)(sp))
        assert abs(got - ref) < 1e-3, (dshard, got, ref)


def test_probs_bf16_close(rng):
    from repro.models.layers import _chunked_attention
    q = jnp.asarray(rng.standard_normal((2, 64, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 64, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 64, 2, 16)), jnp.float32)
    base = _chunked_attention(q, k, v, 0, True, None, 16, 16)
    fast = _chunked_attention(q, k, v, 0, True, None, 16, 16, probs_bf16=True)
    # bf16 probabilities: ~3 decimal digits of precision on the weights
    np.testing.assert_allclose(np.asarray(base), np.asarray(fast),
                               rtol=2e-2, atol=2e-2)


def test_sp_attention_matches_baseline(rng):
    import dataclasses as dc
    from repro.distributed.sharding import axis_map_for, param_specs, tree_named
    from repro.launch.mesh import make_mesh_for
    from repro.models.layers import mesh_context

    mesh = make_mesh_for(8, model_parallel=4)
    for arch in ("llama3.2-1b", "hymba-1.5b"):
        cfg = smoke_config(arch)
        params = M.init_params(cfg, 0)
        batch = {"tokens": jnp.asarray(rng.integers(0, 512, (8, 32)), jnp.int32)}
        ref = M.forward(params, cfg, batch, remat=False)
        cfg_sp = dc.replace(cfg, sp_attention=True, attn_skip_masked_blocks=True)
        pshape = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params)
        sp = jax.device_put(params, tree_named(mesh, param_specs(pshape, mesh)))

        def fwd(p):
            with mesh_context(mesh, axis_map_for(mesh)):
                return M.forward(p, cfg_sp, batch, remat=False)

        got = jax.jit(fwd)(sp)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=2e-3, atol=2e-3)
