"""Pruned-model serving: expert/layer groups as ONE batched dispatch.

A pruned transformer is a pool of small same-geometry BSR weights — E
experts' FFN matrices, or L layers' q-projections.  Dispatching them one
kernel launch at a time leaves the accelerator idle between launches; the
grouped BSR lane stacks the pool behind a leading group axis
(``stack_bsr``) and executes it as a single batched call, bit-identically
to the per-request path.  Three tiers are demonstrated:

1. ``SparseLinearGroup`` — L pruned layers applied in one grouped
   dispatch (differentiable ``spmm`` path and the AOT ``plan_group``
   serving path);
2. ``SparseMoE`` — a capacity-routed MoE whose E experts' wi/wg/wo are
   block-pruned and executed as 3 grouped dispatches per layer instead of
   3·E;
3. the ``SpmmScheduler`` pool — pre-packed BSR skeletons submitted as
   ordinary serving requests group with their bucket-mates and flush as
   one dispatch (``dispatches_per_request`` = 1/G), including DLMC-style
   magnitude/banded/block-random pruning patterns.

Run:  PYTHONPATH=src python examples/pruned_moe_serve.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import SextansEngine
from repro.data.matrices import DLMC_SPARSITIES, magnitude_pruned
from repro.launch.serve import SpmmRequest, serve_spmm_requests
from repro.models.common import Initializer, ModelConfig
from repro.models.layers import SparseLinear, SparseLinearGroup, SparseMoE
from repro.sparse_api import Format, from_dense


def best_of(fn, iters=5):
    fn()
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def main():
    # -- 1. a layer group: 8 pruned projections, one dispatch ---------------
    d_in, d_out, g = 128, 256, 8
    layers, params = zip(*[
        SparseLinear.create(Initializer(10 + i, jnp.float32),
                            d_in, d_out, block=(16, 16), density=0.25)
        for i in range(g)])
    grp = SparseLinearGroup(layers)
    x = jax.random.normal(jax.random.PRNGKey(0), (32, d_in), jnp.float32)

    y_grp = grp(list(params), x, use_plan=True)
    y_seq = jnp.stack([l(p, x) for l, p in zip(layers, params)])
    assert np.array_equal(np.asarray(y_grp), np.asarray(y_seq))
    t_grp = best_of(lambda: jax.block_until_ready(
        grp(list(params), x, use_plan=True)))
    t_seq = best_of(lambda: jax.block_until_ready(
        jnp.stack([l(p, x) for l, p in zip(layers, params)])))
    print(f"[group]     {g} pruned layers, one grouped dispatch: "
          f"{t_seq / t_grp:.2f}x vs per-layer (bit-identical)")

    # -- 2. sparse MoE: E experts, 3 grouped dispatches per layer -----------
    cfg = ModelConfig(name="pruned-moe", family="moe", num_layers=1,
                      d_model=128, num_heads=4, num_kv_heads=4, d_ff=256,
                      vocab_size=256, num_experts=8, experts_per_token=2,
                      moe_group_size=64)
    moe, mp = SparseMoE.create(Initializer(0, jnp.float32), cfg,
                               block=(16, 16), density=0.25)
    xt = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 128), jnp.float32)
    y = moe.apply(mp, cfg, xt)
    gsum = jax.grad(lambda wi: moe.apply({**mp, "wi": wi}, cfg, xt).sum())(
        mp["wi"])
    print(f"[moe]       {cfg.num_experts} experts at density "
          f"{moe.density:.2f}: out {tuple(y.shape)}, grads reach "
          f"{float((np.abs(np.asarray(gsum)) > 0).mean()):.0%} of stacked "
          f"blocks (pad slots pinned to 0)")

    # -- 3. the serving pool: DLMC patterns through the scheduler -----------
    # 16 magnitude-pruned weights at one DLMC sparsity level: the kept-
    # block count is sparsity-determined, so the pool shares one bucket
    # and flushes as a single grouped dispatch.  (A mixed-sparsity pool
    # still groups — one dispatch per occupied kept-block bucket.)
    rng = np.random.default_rng(0)
    sparsity = DLMC_SPARSITIES[2]                       # 0.90
    reqs = []
    for i in range(16):
        w = magnitude_pruned(d_in, d_out, sparsity, block=(16, 16), seed=i)
        reqs.append(SpmmRequest(
            a=from_dense(w.T, format=Format.BSR, block=(16, 16)),
            b=rng.standard_normal((d_in, 32)).astype(np.float32)))

    def engine():
        return SextansEngine(tm=128, k0=128, chunk=8, impl="jnp")

    outs_g, stats_g = serve_spmm_requests(reqs, engine(), batched=True)
    outs_s, _ = serve_spmm_requests(reqs, engine(), batched=False)
    assert all(np.array_equal(a, b) for a, b in zip(outs_g, outs_s))
    t_g = best_of(lambda: serve_spmm_requests(reqs, engine(), batched=True))
    t_s = best_of(lambda: serve_spmm_requests(reqs, engine(), batched=False))
    print(f"[scheduler] {len(reqs)} DLMC-pruned weights -> "
          f"{stats_g['groups']} bucket groups, "
          f"{stats_g['dispatches_per_request']:.2f} disp/req: "
          f"{t_s / t_g:.2f}x grouped vs sequential (bit-identical)")


if __name__ == "__main__":
    main()
