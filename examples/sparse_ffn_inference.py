"""Sparse-weight LM inference — the paper's sparse-DNN use case
(C = 1.0 * A_pruned x B + 0.0 * C, Sec. 2.1) as a model layer.

A reduced llama-family model's FFN weights are magnitude-pruned to
block-sparse form (BSR, 128x128 tiles on the real config; reduced here)
and served through the unified sparse front-end (``SparseTensor`` with
``Format.BSR`` + ``spmm``); outputs are compared against the dense model
with the same masked weights.

Run:  PYTHONPATH=src python examples/sparse_ffn_inference.py
"""

import numpy as np
import jax
import jax.numpy as jnp

import repro.sparse_api as sp
from repro.configs import smoke_config
from repro.models import model as M


def main():
    rng = np.random.default_rng(0)
    cfg = smoke_config("llama3.2-1b")
    params = M.init_params(cfg, seed=0)

    # magnitude-prune FFN up/gate/down to 50% block sparsity (16x16 blocks
    # at this reduced size), then pack to BSR
    tile = 16
    bsr_weights = []
    dense_masked = jax.tree.map(lambda x: x, params)  # copy structure
    for wname in ("wi", "wg", "wo"):
        w_stack = np.asarray(params["layers"]["mlp"][wname], np.float32)
        packed_layers = []
        masked = np.array(w_stack)
        for li in range(w_stack.shape[0]):
            w = w_stack[li]
            k, f = w.shape
            blocks = w.reshape(k // tile, tile, f // tile, tile)
            energy = np.abs(blocks).mean(axis=(1, 3))
            thresh = np.quantile(energy, 0.5)
            keep = energy > thresh
            masked[li] = (blocks * keep[:, None, :, None]).reshape(k, f)
            # SparseTensor orientation: A = W^T of shape (f, k), y = (A@x^T)^T
            packed_layers.append(
                sp.from_dense(masked[li].T, format=sp.Format.BSR,
                              block=(tile, tile)))
        bsr_weights.append(packed_layers)
        dense_masked["layers"]["mlp"][wname] = jnp.asarray(masked)

    # run the dense-masked model
    b, s = 2, 16
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                                   jnp.int32)}
    ref_logits = M.forward(dense_masked, cfg, batch, remat=False)

    # spot-check the BSR path against the masked dense FFN, layer 0
    x = jnp.asarray(rng.standard_normal((8, cfg.d_model)), jnp.float32)
    wi_bsr = bsr_weights[0][0]                       # SparseTensor (f, k)
    y_bsr = sp.spmm(wi_bsr, x.T, backend="pallas", tn=16).T
    y_ref = x @ dense_masked["layers"]["mlp"]["wi"][0]
    err = float(jnp.abs(y_bsr - y_ref).max())
    density = wi_bsr.density
    print(f"FFN block density after pruning: {density:.2f}")
    print(f"BSR kernel vs masked dense: max err {err:.2e}")
    assert err < 1e-4
    assert bool(jnp.isfinite(ref_logits).all())
    print("sparse-FFN inference path OK")
    print("OK")


if __name__ == "__main__":
    main()
