"""Quickstart: general-purpose SpMM through the unified sparse front-end.

One ``SparseTensor`` + one ``spmm`` serves every packed format and backend
(the API analogue of the paper's one-accelerator-serves-any-SpMM claim):

* ``C = alpha * A @ B + beta * C`` with *traced* alpha/beta — sweeping the
  epilogue reuses one compiled executable (HFlex);
* ``A @ b`` operator sugar;
* differentiable end-to-end (``jax.grad`` reaches B, C and the packed
  non-zero values).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax
import jax.numpy as jnp

import repro.sparse_api as sp
from repro.core.sparse import power_law_sparse, spmm_reference


def main():
    rng = np.random.default_rng(0)

    # A: a 1000x800 power-law (social-network-like) sparse matrix
    a = power_law_sparse(1000, 800, avg_nnz_per_row=6, seed=42)
    print(f"A: {a.shape}, nnz={a.nnz}, density={a.density:.4f}")

    n = 64
    b = rng.standard_normal((800, n)).astype(np.float32)
    c = rng.standard_normal((1000, n)).astype(np.float32)
    alpha, beta = 1.0, 0.5

    # Pack once; the Format/backend split is orthogonal: the same tensor
    # runs on "pallas", "pallas_onehot", "jnp", or "auto" dispatch.
    A = sp.from_sparse_matrix(a, tm=128, k0=256, chunk=8)
    print(f"packed: {A.format} geometry={A.geometry} "
          f"(padding handled by Q pointers — HFlex)")
    print(f"registered backends: {sp.list_backends()}")

    out = sp.spmm(A, b, c, alpha, beta, backend="pallas")
    ref = spmm_reference(a, b, c, alpha, beta)
    err = np.abs(np.asarray(out) - ref).max() / (np.abs(ref).max() + 1e-9)
    print(f"max relative error vs oracle: {err:.2e}")
    assert err < 1e-4

    # Operator sugar + autodiff: gradients reach the packed non-zeros.
    y = A @ b
    grad_vals = jax.grad(
        lambda v: jnp.sum(sp.spmm(A.with_values(v), jnp.asarray(b)) ** 2)
    )(A.values)
    print(f"A @ b -> {y.shape}; d(loss)/d(vals) -> {grad_vals.shape}")

    # Epilogue sweeps hit ONE executable: alpha/beta are traced scalars.
    sp.BACKEND_STATS["traces"] = 0
    for alpha_i in (0.1, 0.5, 1.0, 2.0, 4.0):
        sp.spmm(A, b, c, alpha_i, 1.0 - alpha_i, backend="pallas")
    print(f"5-point alpha/beta sweep -> {sp.BACKEND_STATS['traces']} new traces")
    print("OK")


if __name__ == "__main__":
    main()
