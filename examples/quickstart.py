"""Quickstart: general-purpose SpMM with the Sextans engine.

Computes C = alpha*A@B + beta*C for a graph-like sparse matrix through the
full pipeline (Eq.2-4 partitioning -> packing -> Pallas kernel in interpret
mode -> fused epilogue) and checks the result against the numpy oracle.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core.engine import SextansEngine
from repro.core.sparse import power_law_sparse, spmm_reference


def main():
    rng = np.random.default_rng(0)

    # A: a 1000x800 power-law (social-network-like) sparse matrix
    a = power_law_sparse(1000, 800, avg_nnz_per_row=6, seed=42)
    print(f"A: {a.shape}, nnz={a.nnz}, density={a.density:.4f}")

    n = 64
    b = rng.standard_normal((800, n)).astype(np.float32)
    c = rng.standard_normal((1000, n)).astype(np.float32)
    alpha, beta = 1.0, 0.5

    engine = SextansEngine(tm=128, k0=256, chunk=8, impl="pallas")
    packed = engine.pack(a)
    print(f"packed: MBxNWxLW = {packed.geometry}, "
          f"padding handled by Q pointers (HFlex)")

    out = engine.spmm(packed, jnp.asarray(b), jnp.asarray(c), alpha, beta)

    ref = spmm_reference(a, b, c, alpha, beta)
    err = np.abs(np.asarray(out) - ref).max() / (np.abs(ref).max() + 1e-9)
    print(f"max relative error vs oracle: {err:.2e}")
    assert err < 1e-4
    print("OK")


if __name__ == "__main__":
    main()
