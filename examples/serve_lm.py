"""LM serving: prefill a batch of prompts, then greedy-decode tokens
through the KV/state-cache path — the serving loop the decode_32k /
long_500k dry-run cells exercise at production scale, here at CPU scale.

Works for both attention (llama-family) and recurrent (xlstm) caches.

Run:  PYTHONPATH=src python examples/serve_lm.py [--arch llama3.2-1b]
"""

import argparse
import time

import numpy as np
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.launch.serve import lm_generate
from repro.models import model as M


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b",
                    choices=["llama3.2-1b", "xlstm-125m", "hymba-1.5b"])
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    cfg = smoke_config(args.arch)
    params = M.init_params(cfg, seed=0)

    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)

    t0 = time.time()
    out = lm_generate(params, cfg, prompts, steps=args.steps)
    dt = time.time() - t0
    toks = args.batch * args.steps
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"decoded={args.steps}")
    print(f"output tokens shape {out.shape}; "
          f"{toks/dt:.1f} tok/s (CPU, reduced config)")
    assert out.shape == (args.batch, args.steps)
    assert int(out.min()) >= 0 and int(out.max()) < cfg.vocab_size
    # determinism: same prompts -> same greedy continuation
    out2 = lm_generate(params, cfg, prompts, steps=args.steps)
    assert bool((out == out2).all())
    print("greedy decode deterministic OK")
    print("OK")


if __name__ == "__main__":
    main()
