"""Sparse-layer *training* — newly possible with the differentiable spmm.

The legacy kernels were forward-only; ``repro.sparse_api.spmm`` carries a
``jax.custom_vjp``, so gradients flow to the dense activations AND to the
packed non-zero values while the sparsity structure stays fixed — i.e.
training a magnitude-pruned layer.  This trains a block-sparse linear
layer (SparseLinear) to regress a random teacher.

Run:  PYTHONPATH=src python examples/sparse_train.py
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.models.common import Initializer
from repro.models.layers import SparseLinear


def main():
    rng = np.random.default_rng(0)
    d_in, d_out, batch = 64, 96, 128

    init = Initializer(seed=0, dtype=jnp.float32)
    layer, params = SparseLinear.create(init, d_in, d_out, block=(16, 16),
                                        density=0.5)
    print(f"SparseLinear {d_in}->{d_out}, block density "
          f"{layer.density:.2f}, trainable block values "
          f"{params['w'].shape}")

    # Teacher shares the student's sparsity mask, so the student can reach
    # it exactly (a dense teacher would leave an irreducible loss floor).
    mask = (np.asarray(layer.skeleton.todense()) != 0).T        # (d_in, d_out)
    teacher = rng.standard_normal((d_in, d_out)).astype(np.float32) * 0.1 * mask
    x = jnp.asarray(rng.standard_normal((batch, d_in)), jnp.float32)
    y_t = x @ teacher

    def loss_fn(p):
        y = layer(p, x, backend="jnp")
        return jnp.mean((y - y_t) ** 2)

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    # mean-reduced MSE scales grads by 1/d_out — fold that into the lr
    lr = 8.0
    loss0 = None
    for step in range(150):
        loss, g = grad_fn(params)
        params = jax.tree.map(lambda p, gg: p - lr * gg, params, g)
        if loss0 is None:
            loss0 = float(loss)
        if step % 50 == 0:
            print(f"step {step:3d}  loss {float(loss):.5f}")
    final = float(grad_fn(params)[0])
    print(f"loss {loss0:.5f} -> {final:.5f}")
    assert final < 0.1 * loss0, "sparse layer failed to train"
    print("OK")


if __name__ == "__main__":
    main()
