"""End-to-end training driver: a reduced llama3.2-family model trained for
a few hundred steps on the synthetic token stream, with checkpointing and
kill-resume, on whatever devices exist.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""

import argparse
import sys

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="llama3.2-1b")
    args = ap.parse_args()
    return train_main([
        "--arch", args.arch, "--smoke",
        "--steps", str(args.steps),
        "--seq", "64", "--batch", "8", "--lr", "3e-3",
        "--ckpt-every", "100", "--log-every", "20",
    ])


if __name__ == "__main__":
    sys.exit(main())
