"""Serve batched SpMM requests — the paper's deployment scenario.

A stream of graph-propagation requests (C = A_graph @ H + beta*C, the GNN
workload of paper Sec. 2.1) with *different matrix sizes* is served by one
engine. The point being demonstrated is HFlex: after warmup, new problems
hit the executable cache instead of recompiling (the JAX analogue of not
re-running synthesis/place/route per problem).

Run:  PYTHONPATH=src python examples/spmm_serve.py
"""

import time

import numpy as np

from repro.core.engine import SextansEngine
from repro.core.sparse import power_law_sparse, spmm_reference
from repro.launch.serve import SpmmRequest, serve_spmm_requests


def main():
    rng = np.random.default_rng(1)
    engine = SextansEngine(tm=128, k0=256, chunk=8, impl="jnp", bucket=True)

    # 12 requests over graphs of varying size; N = feature width
    requests = []
    for i in range(12):
        nodes = int(rng.integers(500, 2000))
        feats = 32
        a = power_law_sparse(nodes, nodes, avg_nnz_per_row=5, seed=i)
        h = rng.standard_normal((nodes, feats)).astype(np.float32)
        c = np.zeros((nodes, feats), np.float32)
        requests.append(SpmmRequest(a=a, b=h, c=c, alpha=1.0, beta=0.0))

    outs, stats = serve_spmm_requests(requests, engine)

    # verify a few
    for idx in (0, 5, 11):
        r = requests[idx]
        ref = spmm_reference(r.a, r.b, r.c, r.alpha, r.beta)
        err = np.abs(outs[idx] - ref).max() / (np.abs(ref).max() + 1e-9)
        assert err < 1e-4, err

    print(f"served {stats['requests']} SpMM requests "
          f"({stats['gflops']:.2f} GFLOP/s on CPU interpret path)")
    print(f"executable cache hit rate: {stats['executable_cache_hit_rate']:.0%} "
          f"({stats['cache_misses']} compiles for "
          f"{stats['requests']} distinct problems — HFlex)")
    print("OK")


if __name__ == "__main__":
    main()
