"""Serve batched SpMM requests — the paper's deployment scenario.

A stream of graph-propagation requests (C = A_graph @ H + beta*C, the GNN
workload of paper Sec. 2.1) with *different matrix sizes* is served by one
engine.  Two HFlex properties are demonstrated:

1. executable reuse — after warmup, new problems hit the executable cache
   instead of recompiling (the JAX analogue of not re-running
   synthesis/place/route per problem);
2. batched group dispatch — requests whose packed geometry lands in the
   same bucket are stacked by the serving scheduler and executed as ONE
   compiled call (``dispatches_per_request`` < 1), bit-identically to
   per-request execution;
3. out-of-core streaming — one "web-scale" graph whose packed payload
   exceeds an artificial ``device_bytes`` budget rides the scheduler's
   streaming lane: K0-window chunks through a persistent C accumulator,
   still bit-identical, never holding the full payload on device;
4. async pipeline — the same pool served through
   ``async_pipeline=True``: ``submit()`` returns futures immediately,
   host-resident packing (``pack_hflex(device=False)``) runs on worker
   threads and overlaps device execution (``pack_hidden_fraction``),
   results bit-identical to the synchronous pass and in submit order.

Run:  PYTHONPATH=src python examples/spmm_serve.py
"""

import numpy as np

from repro.core.engine import SextansEngine
from repro.core.sparse import power_law_sparse, spmm_reference
from repro.launch.serve import SpmmRequest, serve_spmm_requests


def main():
    rng = np.random.default_rng(1)
    engine = SextansEngine(tm=128, k0=256, chunk=8, impl="jnp", bucket=True)

    # 18 requests: 12 same-sized graphs (bucket-mates -> one group
    # dispatch) + 6 of varying size; N = feature width, ragged on purpose.
    requests = []
    for i in range(12):
        nodes, feats = 1024, 32 if i % 2 else 24
        a = power_law_sparse(nodes, nodes, avg_nnz_per_row=5, seed=i)
        h = rng.standard_normal((nodes, feats)).astype(np.float32)
        c = np.zeros((nodes, feats), np.float32)
        requests.append(SpmmRequest(a=a, b=h, c=c, alpha=1.0, beta=0.0))
    for i in range(6):
        nodes = int(rng.integers(500, 2000))
        a = power_law_sparse(nodes, nodes, avg_nnz_per_row=5, seed=100 + i)
        h = rng.standard_normal((nodes, 32)).astype(np.float32)
        requests.append(SpmmRequest(a=a, b=h))
    # one oversized graph: payload >> the artificial device budget below,
    # so the scheduler must stream it window by window
    big = power_law_sparse(2048, 8192, avg_nnz_per_row=6, seed=999)
    requests.append(SpmmRequest(
        a=big, b=rng.standard_normal((8192, 32)).astype(np.float32)))

    # size the budget on a probe engine so the serving stats below count
    # only the scheduler's own packs
    probe = SextansEngine(tm=128, k0=256, chunk=8, impl="jnp", bucket=True)
    big_payload = probe.pack(big).nbytes
    device_bytes = big_payload // 4                 # cap < payload/4
    outs, stats = serve_spmm_requests(requests, engine,
                                      device_bytes=device_bytes)

    # verify a few (including the streamed one, last in the pool)
    for idx in (0, 5, 14, len(requests) - 1):
        r = requests[idx]
        c = r.c if r.c is not None else np.zeros_like(outs[idx])
        ref = spmm_reference(r.a, r.b, c, r.alpha, r.beta)
        err = np.abs(outs[idx] - ref).max() / (np.abs(ref).max() + 1e-9)
        assert err < 1e-4, err
    assert stats["streamed"] == 1, stats
    assert stats["window_dispatches"] > 1, stats

    print(f"served {stats['requests']} SpMM requests "
          f"({stats['compute_gflops']:.2f} GFLOP/s execute, "
          f"{stats['gflops']:.2f} GFLOP/s incl. preprocessing)")
    print(f"executable cache hit rate: {stats['executable_cache_hit_rate']:.0%} "
          f"({stats['cache_misses']} compiles for "
          f"{stats['requests']} distinct problems — HFlex)")
    print(f"batched grouping: {stats['groups']} group dispatches for "
          f"{stats['requests']} requests "
          f"({stats['batched_fraction']:.0%} of traffic rode a group, "
          f"{stats['dispatches_per_request']:.2f} dispatches/request)")
    print(f"out-of-core lane: {stats['streamed']} oversized request "
          f"streamed in {stats['window_dispatches']} window dispatches, "
          f"peak device working set {stats['peak_payload_bytes']:,} B "
          f"(vs {big_payload:,} B payload)")

    # the same pool through the async pack/execute pipeline: futures out,
    # host packing overlapped with device execution, bit-identical results
    async_engine = SextansEngine(tm=128, k0=256, chunk=8, impl="jnp",
                                 bucket=True)
    outs_async, astats = serve_spmm_requests(
        requests, async_engine, async_pipeline=True,
        device_bytes=device_bytes)
    for y_sync, y_async in zip(outs, outs_async):
        assert np.array_equal(y_sync, y_async), "async diverged"
    print(f"async pipeline: bit-identical to the synchronous pass, "
          f"{astats['pack_hidden_fraction']:.0%} of pack time hidden "
          f"behind execution ({astats['overlap_s'] * 1e3:.1f} ms "
          f"overlapped)")
    print("OK")


if __name__ == "__main__":
    main()
