"""Generate the §Dry-run and §Roofline markdown tables for EXPERIMENTS.md
from out/dryrun/*.json.

Run:  PYTHONPATH=src python -m benchmarks.report_tables [--suffix ""]
"""

from __future__ import annotations

import argparse
import json
import pathlib

OUT = pathlib.Path(__file__).resolve().parents[1] / "out" / "dryrun"


def fmt_b(x: float) -> str:
    for unit, div in (("TB", 2**40), ("GB", 2**30), ("MB", 2**20)):
        if x >= div:
            return f"{x/div:.1f}{unit}"
    return f"{x:.0f}B"


def load(suffix: str = ""):
    rows = []
    for f in sorted(OUT.glob("*.json")):
        stem = f.stem
        # baseline files end exactly in __single / __multi; lever runs carry
        # an extra _<tag> suffix and are excluded unless requested
        tail = stem.split("__")[-1]
        if suffix:
            if not tail.endswith(suffix):
                continue
        elif tail not in ("single", "multi"):
            continue
        rows.append(json.loads(f.read_text()))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--suffix", default="")
    args = ap.parse_args()
    rows = [r for r in load(args.suffix)]
    ok = [r for r in rows if r.get("status") == "ok"]
    sk = [r for r in rows if r.get("status") == "skipped"]

    print("### Dry-run table (per-device, compiled artifacts)\n")
    print("| arch | shape | mesh | chips | HLO GFLOPs/chip | HBM bytes/chip "
          "| wire bytes | x-pod bytes | peak mem/chip | compile s |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in ok:
        c = r["cost"]
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['chips']} "
              f"| {c['flops']/1e9:,.0f} | {fmt_b(c['bytes_accessed'])} "
              f"| {fmt_b(r['collectives']['wire_bytes'])} "
              f"| {fmt_b(r['collectives']['cross_pod_bytes'])} "
              f"| {fmt_b(r['memory']['peak_bytes_per_device'])} "
              f"| {r['compile_s']:.0f} |")
    print()
    for r in sk:
        print(f"- **skipped** {r['arch']} x {r['shape']} ({r['mesh']}): "
              f"{r['reason']}")

    print("\n### Roofline table (TPU v5e terms, seconds/step/chip)\n")
    print("| arch | shape | mesh | compute | memory | collective | dominant "
          "| MODEL_FLOPS/chip | useful ratio | roofline frac |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in ok:
        t = r["roofline"]
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
              f"| {t['compute_s']:.3g} | {t['memory_s']:.3g} "
              f"| {t['collective_s']:.3g} | **{t['dominant']}** "
              f"| {t['model_flops_per_chip']:.3g} "
              f"| {t['useful_flops_ratio']:.2f} | {t['mfu_bound']:.4f} |")


if __name__ == "__main__":
    main()
