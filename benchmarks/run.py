"""Benchmark harness — one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (and, with ``--json PATH``,
writes the same rows as machine-readable JSON so the BENCH_*.json perf
trajectory can accumulate across PRs):

  table1_*   — speedup breakdown (paper Table 1): OoO / PUs / PEs
  fig7_*     — geomean speedups vs modeled GPUs (paper Fig. 7 headline)
  fig8_peak  — peak throughput (paper Fig. 8 / Table 3)
  fig9_*     — memory bandwidth utilization geomean (paper Fig. 9)
  fig10_*    — energy efficiency geomean (paper Fig. 10)
  kernel_*   — Pallas/jnp SpMM microbenchmarks (wall-clock, CPU interpret)
  plan_spmm  — SpmmPlan.run vs unplanned spmm (bit-identity asserted)
  sched_*    — scheduler preprocessing throughput + bubble fraction
               (vectorized production scheduler vs exact-greedy reference)
  serve_*    — batched (geometry-bucketing scheduler) vs sequential vs
               async-pipelined (futures + pack/execute overlap) serving
               on a mixed pool of bucket-mates (bit-identity asserted;
               requests/s, dispatches/request, pack_hidden_fraction)
  slo_*      — continuous batching under a seeded Poisson arrival
               process: deadline-driven background flusher + cost-model
               near-miss merging + epilogue folding vs exact-key
               caller-driven flush-per-arrival (bit-identity asserted;
               p50/p99 latency, dispatches/request, merged groups)
  bsr_serve_* — pruned-model serving lane: pools of same-geometry BSR
               weights (DLMC patterns, llama/qwen FFN geometries) served
               grouped (one batched dispatch per bucket) vs per-request
               (bit-identity asserted; requests/s, dispatches/request)
  stream_*   — out-of-core 2-D (K-window x N-tile) streaming vs the
               resident plan at several device_bytes caps, including a
               huge-N case whose budget forces column tiling
               (bit-identity asserted; Mnnz/s, window dispatches, column
               tiles, peak device working set)
  spmv_*     — skinny-N (N in {1, 4, 8}) SpMV fast lane vs the tall-N
               kernel at the same widths (bit-identity asserted; Mnnz/s,
               speedup ratio) plus an auto-routed serving pool reporting
               skinny_dispatches
  autotune_* — autotuned execution geometry + the persistent tuning/plan
               cache: default vs measured-best plans on a DLMC pruned
               pattern at the skinny boundary and on forced streaming
               (bit-identity asserted), cold vs warm plan-build time, and
               a fresh-process warm start over the same SEXTANS_TUNE_DIR

All wall-clock numbers use ``time.perf_counter`` (monotonic,
high-resolution); JAX results are ``block_until_ready``-fenced.

Run:  PYTHONPATH=src python -m benchmarks.run [--budget small|full]
                                              [--json PATH]
                                              [--only SUBSTR]

``--compare OLD.json NEW.json [--tolerance R]`` diffs two ``--json``
snapshots row-by-row (ratio new/old) and exits 2 on any regression beyond
the tolerance — the BENCH_*.json trajectory as a PR gate.
"""

from __future__ import annotations

import argparse
import json
import time
from typing import List, Optional

import numpy as np

# Collected rows of the current invocation:
# {"name", "us", "derived"[, "extra"]} — "extra" carries structured
# key/value metrics for machine consumers (the CI serve-smoke assert).
ROWS: List[dict] = []


def _row(name: str, us: float, derived: str,
         extra: Optional[dict] = None) -> None:
    row = {"name": name, "us": us, "derived": derived}
    if extra is not None:
        row["extra"] = extra
    ROWS.append(row)
    print(f"{name},{us:.1f},{derived}")


def bench_table1() -> None:
    from repro.core.perfmodel import table1_breakdown
    from repro.core.sparse import banded_sparse

    a = banded_sparse(3000, 3000, 12, seed=1)   # crystm03-like (scaled)
    t0 = time.perf_counter()
    t = table1_breakdown(a, n=8)
    us = (time.perf_counter() - t0) * 1e6
    _row("table1_incr_ooo", us, f"{t['incr_ooo']:.2f}x_paper_9.97x")
    _row("table1_incr_pus", us, f"{t['incr_pus']:.2f}x_paper_7.97x")
    _row("table1_incr_pes", us, f"{t['incr_pes']:.2f}x_paper_45.3x")
    _row("table1_accum", us, f"{t['accum_pes']:.0f}x_paper_3608x")


def bench_fig7(budget: str) -> None:
    from repro.core.partition import SextansParams
    from repro.core.perfmodel import (
        PLATFORMS, event_cycles, gpu_model_time, platform_time,
        throughput_gflops)
    from repro.data.matrices import paper_n_values, suite

    pp = SextansParams()
    entries = suite(budget)
    ratios_k80, ratios_v100 = [], []
    peak = {"SEXTANS": 0.0, "SEXTANS-P": 0.0}
    t0 = time.perf_counter()
    for e in entries:
        for n in paper_n_values(budget):
            cyc = event_cycles(e.matrix, n, pp)
            ts = platform_time(e.matrix, n, PLATFORMS["SEXTANS"], pp, cycles=cyc)
            # Sextans-P: same architecture, 350 MHz + V100 bandwidth
            tsp = max(cyc / PLATFORMS["SEXTANS-P"].freq_hz,
                      e.matrix.memory_traffic_bytes(n)
                      / PLATFORMS["SEXTANS-P"].bw_Bps)
            tk = gpu_model_time(e.matrix, n, PLATFORMS["K80"])
            tv = gpu_model_time(e.matrix, n, PLATFORMS["V100"])
            ratios_k80.append(tk / ts)
            ratios_v100.append(tv / tsp)
            peak["SEXTANS"] = max(peak["SEXTANS"],
                                  throughput_gflops(e.matrix, n, ts))
            peak["SEXTANS-P"] = max(peak["SEXTANS-P"],
                                    throughput_gflops(e.matrix, n, tsp))
    us = (time.perf_counter() - t0) * 1e6 / max(len(ratios_k80), 1)
    geo_k = float(np.exp(np.mean(np.log(ratios_k80))))
    geo_v = float(np.exp(np.mean(np.log(ratios_v100))))
    _row("fig7_geomean_vs_k80", us, f"{geo_k:.2f}x_paper_2.50x")
    _row("fig7_geomean_p_vs_v100", us, f"{geo_v:.2f}x_paper_1.14x")
    _row("fig8_peak_gflops", us, f"{peak['SEXTANS']:.0f}_paper_181.1")
    _row("fig8_peak_p_gflops", us, f"{peak['SEXTANS-P']:.0f}_paper_343.6")


def bench_fig9_fig10(budget: str) -> None:
    from repro.core.partition import SextansParams
    from repro.core.perfmodel import (
        PLATFORMS, bandwidth_utilization, event_cycles, gpu_model_time,
        platform_time)
    from repro.data.matrices import paper_n_values, suite

    pp = SextansParams()
    entries = suite(budget)
    utils = {"SEXTANS": [], "K80": []}
    eff = {"SEXTANS": [], "K80": []}
    t0 = time.perf_counter()
    count = 0
    for e in entries:
        for n in paper_n_values(budget):
            count += 1
            cyc = event_cycles(e.matrix, n, pp)
            ts = platform_time(e.matrix, n, PLATFORMS["SEXTANS"], pp, cycles=cyc)
            tk = gpu_model_time(e.matrix, n, PLATFORMS["K80"])
            utils["SEXTANS"].append(
                bandwidth_utilization(e.matrix, n, ts, PLATFORMS["SEXTANS"]))
            utils["K80"].append(
                bandwidth_utilization(e.matrix, n, tk, PLATFORMS["K80"]))
            p = e.matrix.problem_size_flop(n)
            eff["SEXTANS"].append(p / ts / PLATFORMS["SEXTANS"].power_W)
            eff["K80"].append(p / tk / PLATFORMS["K80"].power_W)
    us = (time.perf_counter() - t0) * 1e6 / max(count, 1)
    gu_s = float(np.exp(np.mean(np.log(utils["SEXTANS"]))))
    gu_k = float(np.exp(np.mean(np.log(utils["K80"]))))
    _row("fig9_bw_util_sextans", us, f"{gu_s:.4f}_paper_0.0385")
    _row("fig9_bw_util_k80", us, f"{gu_k:.4f}_paper_0.0147")
    ge_s = float(np.exp(np.mean(np.log(eff["SEXTANS"]))))
    ge_k = float(np.exp(np.mean(np.log(eff["K80"]))))
    _row("fig10_energy_ratio_vs_k80", us, f"{ge_s/ge_k:.2f}x_paper_6.25x")


def bench_hub_split(budget: str) -> None:
    """Beyond-paper: virtual-sub-row splitting for hub rows (the paper's
    OoO scheduler cannot fill a PE whose window is serialized by one heavy
    row). Reports the geomean-vs-K80 recovery on the power-law subset."""
    from repro.core.partition import SextansParams
    from repro.core.perfmodel import (
        PLATFORMS, event_cycles, gpu_model_time, platform_time)
    from repro.data.matrices import paper_n_values, suite

    pp = SextansParams()
    entries = [e for e in suite(budget) if e.family == "power_law"]
    base, split = [], []
    t0 = time.perf_counter()
    for e in entries:
        for n in paper_n_values(budget):
            tk = gpu_model_time(e.matrix, n, PLATFORMS["K80"])
            t_b = platform_time(e.matrix, n, PLATFORMS["SEXTANS"], pp,
                                cycles=event_cycles(e.matrix, n, pp))
            t_s = platform_time(e.matrix, n, PLATFORMS["SEXTANS"], pp,
                                cycles=event_cycles(e.matrix, n, pp,
                                                    hub_split=4 * pp.D))
            base.append(tk / t_b)
            split.append(tk / t_s)
    us = (time.perf_counter() - t0) * 1e6 / max(len(base), 1)
    gb = float(np.exp(np.mean(np.log(base))))
    gs = float(np.exp(np.mean(np.log(split))))
    _row("hubsplit_powerlaw_vs_k80", us, f"{gb:.2f}x->{gs:.2f}x_beyond_paper")


def _time_call(fn, iters: int = 5) -> float:
    """Best-of-``iters`` wall clock (timeit practice: the minimum is the
    least noise-contaminated estimate). Warms once for compile/caches."""
    fn()  # warm / compile
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def bench_kernels() -> None:
    import jax.numpy as jnp

    import repro.sparse_api as sp
    from repro.core.sparse import power_law_sparse

    rng = np.random.default_rng(0)
    a = power_law_sparse(512, 512, 6, seed=1)
    b = jnp.asarray(rng.standard_normal((512, 64)), jnp.float32)
    A = sp.from_sparse_matrix(a, tm=128, k0=128, chunk=8, bucket=False)
    for backend in ("pallas", "pallas_onehot", "jnp"):
        us = _time_call(
            lambda: sp.spmm(A, b, backend=backend).block_until_ready())
        gf = a.problem_size_flop(64) / (us / 1e6) / 1e9
        _row(f"kernel_spmm_{backend}", us, f"{gf:.3f}GFLOPs_cpu_interpret")


def bench_plan() -> None:
    """SpmmPlan.run vs unplanned spmm on the jnp (CPU production) backend.

    Asserts bit-identity between the two paths before timing — the plan is
    a dispatch/precompute optimization, never a numerics change."""
    import jax.numpy as jnp

    import repro.sparse_api as sp
    from repro.core.sparse import power_law_sparse

    rng = np.random.default_rng(0)
    a = power_law_sparse(512, 512, 6, seed=1)
    b = jnp.asarray(rng.standard_normal((512, 64)), jnp.float32)
    A = sp.from_sparse_matrix(a, tm=128, k0=128, chunk=8, bucket=True)
    plan = sp.plan(A, 64, backend="jnp")
    y_plan = np.asarray(plan.run(b))
    y_unpl = np.asarray(sp.spmm(A, b, backend="jnp"))
    assert np.array_equal(y_plan, y_unpl), "plan.run diverged from spmm"
    us_u = _time_call(
        lambda: sp.spmm(A, b, backend="jnp").block_until_ready(), iters=20)
    us_p = _time_call(lambda: plan.run(b).block_until_ready(), iters=20)
    _row("plan_spmm_unplanned", us_u, "jnp_backend")
    _row("plan_spmm", us_p, f"{us_u / us_p:.2f}x_vs_unplanned_bitexact")


def bench_scheduler() -> None:
    from repro.core.hflex import pack_pe_streams
    from repro.core.partition import SextansParams
    from repro.core.sparse import power_law_sparse

    a = power_law_sparse(20_000, 20_000, 6, seed=2)
    pp = SextansParams(K0=4096, P=64, D=10)

    def one(mode: str, iters: int) -> None:
        ps = pack_pe_streams(a, pp, mode=mode)
        us = _time_call(lambda: pack_pe_streams(a, pp, mode=mode),
                        iters=iters)
        nnz_per_s = a.nnz / (us / 1e6)
        name = "sched_preprocess" if mode == "vectorized" else \
            f"sched_preprocess_{mode}"
        _row(name, us,
             f"{nnz_per_s/1e6:.2f}Mnnz/s_bubbles_{ps.bubble_fraction:.3f}")

    one("vectorized", iters=10)    # the production preprocessing path
    one("greedy", iters=2)         # exact-greedy reference (paper Fig. 5)


def bench_serve() -> None:
    """Batched vs sequential vs async-pipelined serving on a mixed pool of
    32 bucket-mates (plus a few odd-geometry singletons): the batched rows
    measure dispatch amortization (one batch-grid dispatch per bucket
    group), the ``serve_async`` row measures the futures-based
    pack/execute overlap on top of it — host packing runs on worker
    threads while the device computes, reported as
    ``pack_hidden_fraction``.  Bit-identity across all three paths is
    asserted before timing."""
    from repro.core.engine import SextansEngine
    from repro.core.sparse import power_law_sparse, random_sparse
    from repro.launch.serve import SpmmRequest, serve_spmm_requests

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(32):                     # one bucket: 32 mates, ragged N
        a = power_law_sparse(512, 512, 5, seed=i)
        n = 24 if i % 2 else 32             # both pad to the N=32 bucket
        reqs.append(SpmmRequest(
            a=a, b=rng.standard_normal((512, n)).astype(np.float32)))
    for i in range(4):                      # odd geometries -> singletons
        a = random_sparse(200 + 40 * i, 300, 0.02, seed=100 + i)
        reqs.append(SpmmRequest(
            a=a, b=rng.standard_normal((300, 32)).astype(np.float32)))

    def engine():
        return SextansEngine(tm=128, k0=128, chunk=8, impl="jnp")

    # warm all paths (compiles), then assert bit-identity
    outs_b, _ = serve_spmm_requests(reqs, engine(), batched=True)
    outs_s, _ = serve_spmm_requests(reqs, engine(), batched=False)
    outs_a, _ = serve_spmm_requests(reqs, engine(), async_pipeline=True)
    for x, y in zip(outs_b, outs_s):
        assert np.array_equal(x, y), "batched serving diverged"
    for x, y in zip(outs_b, outs_a):
        assert np.array_equal(x, y), "async serving diverged from batched"

    for mode, kw in (("serve_batched", dict(batched=True)),
                     ("serve_sequential", dict(batched=False)),
                     ("serve_async", dict(async_pipeline=True))):
        best = None
        for _ in range(3):
            t0 = time.perf_counter()
            _, stats = serve_spmm_requests(reqs, engine(), **kw)
            dt = time.perf_counter() - t0
            if best is None or dt < best[0]:
                best = (dt, stats)
        dt, stats = best
        us = dt * 1e6 / len(reqs)
        rps = len(reqs) / dt
        dpr = stats["dispatches_per_request"]
        hidden = stats["pack_hidden_fraction"]
        derived = (f"{rps:.0f}req/s_{dpr:.3f}disp/req_"
                   f"bf{stats['batched_fraction']:.2f}")
        if mode == "serve_async":
            derived += f"_packhidden{hidden:.2f}_bitexact_vs_batched"
        _row(mode, us, derived,
             extra={
                 "requests_per_s": rps,
                 "dispatches_per_request": dpr,
                 "batched_fraction": stats["batched_fraction"],
                 "groups": stats["groups"],
                 "compute_gflops": stats["compute_gflops"],
                 "pack_hidden_fraction": hidden,
                 "overlap_s": stats["overlap_s"],
                 "bit_identical": True,
             })


def bench_slo() -> None:
    """Continuous batching under load: a seeded Poisson arrival process
    over a mixed near-miss pool (two adjacent LW buckets, per-request
    ``(alpha, beta)`` drawn from a small set, tight deadlines) served two
    ways.  The ``slo_caller_flush`` baseline is the exact-key scheduler
    flushed at every arrival — one dispatch per request, saturating the
    dispatch thread so queueing delay dominates the tail.  The
    ``slo_continuous`` lane is the deadline-driven background flusher
    with the cost-model policy: near-miss buckets merge into padded
    groups, epilogues fold into per-member vectors, and admission waits
    for cost-model fullness or deadline urgency.  Both lanes replay the
    SAME seeded arrival schedule; both are asserted bit-identical to the
    per-request engine reference before anything is reported."""
    from repro.core.engine import SextansEngine
    from repro.core.sparse import power_law_sparse
    from repro.launch.policy import MergePolicy
    from repro.launch.serve import SpmmRequest, SpmmScheduler

    rng = np.random.default_rng(7)
    reqs = []
    for i in range(96):                 # adjacent LW buckets: 3 vs 6 nnz/row
        a = power_law_sparse(256, 256, 3 if i % 2 == 0 else 6, seed=i)
        b = rng.standard_normal((256, 24)).astype(np.float32)
        c = rng.standard_normal((256, 24)).astype(np.float32)
        reqs.append(SpmmRequest(a=a, b=b, c=c, alpha=[1.0, 0.5, 2.0][i % 3],
                                beta=[0.0, 1.0][i % 2]))
    # one fixed Poisson schedule (mean gap 300us) replayed by both lanes
    gaps = np.random.default_rng(42).exponential(3e-4, size=len(reqs))
    deadline_s = 0.01

    def engine():
        return SextansEngine(tm=128, k0=512, chunk=8, impl="jnp")

    eng_ref = engine()
    refs = [np.asarray(eng_ref.spmm(eng_ref.pack(r.a), r.b, r.c,
                                    r.alpha, r.beta)) for r in reqs]

    def paced_submit(submit_fn):
        futs, nxt = [], time.monotonic()
        for r, gap in zip(reqs, gaps):
            nxt += gap
            wait = nxt - time.monotonic()
            if wait > 0:
                time.sleep(wait)
            futs.append(submit_fn(r))
        return futs

    def run_caller_flush():
        sched = SpmmScheduler(engine(), async_pipeline=True)
        t0 = time.perf_counter()
        futs = paced_submit(lambda r: (sched.submit(r), sched.flush())[0])
        outs = [f.result(timeout=300) for f in futs]
        dt = time.perf_counter() - t0
        res = (outs, dict(sched.stats), sched.latency_p50,
               sched.latency_p99, dt)
        sched.shutdown()
        return res

    def run_continuous():
        sched = SpmmScheduler(
            engine(), async_pipeline=True, background_flush=True,
            policy=MergePolicy(dispatch_overhead_cycles=5e5),
            flush_poll_s=0.002)
        t0 = time.perf_counter()
        futs = paced_submit(lambda r: sched.submit(SpmmRequest(
            a=r.a, b=r.b, c=r.c, alpha=r.alpha, beta=r.beta,
            deadline_s=deadline_s)))
        outs = [f.result(timeout=300) for f in futs]
        dt = time.perf_counter() - t0
        res = (outs, dict(sched.stats), sched.latency_p50,
               sched.latency_p99, dt)
        sched.shutdown()
        return res

    rows = {}
    for name, run in (("slo_caller_flush", run_caller_flush),
                      ("slo_continuous", run_continuous)):
        best = None
        for rep in range(3):            # rep 0 warms compiles (G buckets,
            outs, st, p50, p99, dt = run()  # merged-lw geometry)
            for o, ref in zip(outs, refs):
                assert np.array_equal(o, ref), f"{name} diverged"
            if rep == 0:
                continue
            if best is None or p99 < best[2]:
                best = (st, p50, p99, dt)
        st, p50, p99, dt = best
        dpr = st["dispatches"] / st["requests"]
        rows[name] = (st, p50, p99, dpr)
        _row(name, p99 * 1e6,
             f"p50_{p50*1e3:.1f}ms_p99_{p99*1e3:.1f}ms_"
             f"{dpr:.3f}disp/req_bitexact",
             extra={
                 "latency_p50_ms": p50 * 1e3,
                 "latency_p99_ms": p99 * 1e3,
                 "dispatches_per_request": dpr,
                 "requests_per_s": st["requests"] / dt,
                 "merged_groups": st["merged_groups"],
                 "merge_saved_dispatches": st["merge_saved_dispatches"],
                 "folded_requests": st["folded_requests"],
                 "flusher_flushes": st["flusher_flushes"],
                 "deadline_s": deadline_s,
                 "bit_identical": True,
             })
    (st_b, _, p99_b, dpr_b) = rows["slo_caller_flush"]
    (st_c, _, p99_c, dpr_c) = rows["slo_continuous"]
    _row("slo_dispatch_savings", 0.0,
         f"{dpr_b/dpr_c:.1f}x_fewer_dispatches_"
         f"p99_{p99_b/p99_c:.2f}x_better",
         extra={
             "dispatch_reduction_x": dpr_b / dpr_c,
             "p99_speedup_x": p99_b / p99_c,
             "merged_groups": st_c["merged_groups"],
         })


def bench_stream() -> None:
    """Out-of-core 2-D (K-window x N-tile) streaming vs the resident plan
    at several ``device_bytes`` caps: achieved Mnnz/s, window dispatches
    per run, column tiles, and the device working set
    (peak_payload_bytes) actually pinned.  Streaming is bit-identical to
    the resident path — asserted before timing — so the rows measure pure
    pipeline overhead: what it costs to run a matrix the chip could not
    hold.  The ``huge_n`` row caps the budget below one full-N window
    chunk, so the plan must tile the dense operand's columns too
    (``n_tiles > 1``) — tiled runs return host numpy, hence the
    ``jax.block_until_ready`` fence (a no-op on numpy)."""
    import jax

    import repro.sparse_api as sp
    from repro.core.sparse import power_law_sparse

    rng = np.random.default_rng(0)
    a = power_law_sparse(1024, 8192, 6, seed=3)
    A = sp.from_sparse_matrix(a, tm=128, k0=128, chunk=8, bucket=True)
    n = 16
    b = rng.standard_normal((8192, n)).astype(np.float32)
    payload = A.nbytes

    resident = sp.plan(A, n, backend="jnp")
    y_ref = np.asarray(resident.run(b))
    us_r = _time_call(lambda: resident.run(b).block_until_ready(), iters=10)
    mnnz_r = a.nnz / (us_r / 1e6) / 1e6
    _row("stream_spmm_resident", us_r,
         f"{mnnz_r:.1f}Mnnz/s_payload{payload}B",
         extra={"payload_bytes": payload, "mnnz_per_s": mnnz_r})

    for frac in (4, 16, 64):
        cap = payload // frac
        P = sp.plan(A, n, backend="jnp", device_bytes=cap)
        assert isinstance(P, sp.StreamingPlan), "cap did not select streaming"
        y = np.asarray(P.run(b))
        bitexact = bool(np.array_equal(y, y_ref))
        assert bitexact, "streaming diverged from resident plan"
        us = _time_call(lambda: jax.block_until_ready(P.run(b)), iters=10)
        mnnz = a.nnz / (us / 1e6) / 1e6
        _row(f"stream_spmm_cap_payload/{frac}", us,
             f"{mnnz:.1f}Mnnz/s_{P.window_dispatches}disp_"
             f"wc{P.window_chunk}_nt{P.n_tiles}_bitexact",
             extra={
                 "streamed": 1,
                 "device_bytes": cap,
                 "window_dispatches": P.window_dispatches,
                 "window_chunk": P.window_chunk,
                 "n_tile": P.n_tile,
                 "n_tiles": P.n_tiles,
                 "peak_payload_bytes": P.peak_payload_bytes,
                 "payload_bytes": payload,
                 "mnnz_per_s": mnnz,
                 "bit_identical": bitexact,
             })

    # huge-N: the budget holds less than ONE full-N window chunk, so the
    # 2-D grid must tile columns as well as windows
    n_huge = 256
    b_huge = rng.standard_normal((8192, n_huge)).astype(np.float32)
    ref_huge = np.asarray(sp.plan(A, n_huge, backend="jnp").run(b_huge))
    floor = sp.plan(A, n_huge, backend="jnp", stream=True,
                    window_chunk=1).peak_payload_bytes
    cap = min(int(floor * 0.5), payload)
    P = sp.plan(A, n_huge, backend="jnp", device_bytes=cap)
    assert isinstance(P, sp.StreamingPlan), "cap did not select streaming"
    assert P.n_tiles > 1, "budget failed to force column tiling"
    y = P.run(b_huge)
    assert isinstance(y, np.ndarray)
    bitexact = bool(np.array_equal(y, ref_huge))
    assert bitexact, "2-D streaming diverged from resident plan"
    us = _time_call(lambda: jax.block_until_ready(P.run(b_huge)), iters=5)
    mnnz = a.nnz / (us / 1e6) / 1e6
    _row("stream_spmm_2d_huge_n", us,
         f"{mnnz:.1f}Mnnz/s_{P.window_dispatches}disp_wc{P.window_chunk}_"
         f"nt{P.n_tiles}_bitexact",
         extra={
             "streamed": 1,
             "device_bytes": cap,
             "window_dispatches": P.window_dispatches,
             "window_chunk": P.window_chunk,
             "n_tile": P.n_tile,
             "n_tiles": P.n_tiles,
             "peak_payload_bytes": P.peak_payload_bytes,
             "payload_bytes": payload,
             "mnnz_per_s": mnnz,
             "bit_identical": bitexact,
         })


def bench_spmv() -> None:
    """Skinny-N SpMV fast lane vs the tall-N kernel at N in {1, 4, 8}:
    the lane drops the NT grid dimension and pads N to 8 lanes instead of
    TN=128, so every B window streams once and >90% of the padding work
    disappears.  Results are bit-identical (asserted); the ratio is the
    lane's speedup at that width.  The ``serve_pool`` row routes a skinny
    request pool through ``impl="auto"`` and reports the scheduler's
    ``skinny_dispatches`` accounting."""
    import jax.numpy as jnp

    import repro.sparse_api as sp
    from repro.core.engine import SextansEngine
    from repro.core.sparse import power_law_sparse
    from repro.launch.serve import SpmmRequest, serve_spmm_requests

    rng = np.random.default_rng(0)
    a = power_law_sparse(512, 1024, 6, seed=1)
    A = sp.from_sparse_matrix(a, tm=128, k0=128, chunk=8, bucket=True)
    for n in (1, 4, 8):
        b = jnp.asarray(rng.standard_normal((1024, n)), jnp.float32)
        y_tall = np.asarray(sp.spmm(A, b, backend="pallas", tn=128,
                                    interpret=True))
        y_skinny = np.asarray(sp.spmm(A, b, backend="spmv", interpret=True))
        bitexact = bool(np.array_equal(y_skinny, y_tall))
        assert bitexact, f"spmv lane diverged from tall-N kernel at N={n}"
        us_t = _time_call(lambda: sp.spmm(
            A, b, backend="pallas", tn=128,
            interpret=True).block_until_ready())
        us_s = _time_call(lambda: sp.spmm(
            A, b, backend="spmv", interpret=True).block_until_ready())
        mnnz_t = a.nnz / (us_t / 1e6) / 1e6
        mnnz_s = a.nnz / (us_s / 1e6) / 1e6
        ratio = us_t / us_s
        _row(f"spmv_n{n}_tall", us_t, f"{mnnz_t:.2f}Mnnz/s_tn128",
             extra={"n": n, "mnnz_per_s": mnnz_t})
        _row(f"spmv_n{n}_skinny", us_s,
             f"{mnnz_s:.2f}Mnnz/s_{ratio:.2f}x_vs_talln_bitexact",
             extra={"n": n, "mnnz_per_s": mnnz_s,
                    "speedup_vs_talln": ratio, "bit_identical": bitexact})

    # auto-routed skinny pool: the scheduler must count the lane
    reqs = [SpmmRequest(
        a=power_law_sparse(256, 320, 5, seed=i),
        b=rng.standard_normal((320, 4)).astype(np.float32))
        for i in range(8)]
    t0 = time.perf_counter()
    _, stats = serve_spmm_requests(
        reqs, SextansEngine(tm=128, k0=128, chunk=8, impl="auto"))
    dt = time.perf_counter() - t0
    assert stats["skinny_dispatches"] > 0, "auto pool missed the SpMV lane"
    _row("spmv_serve_pool", dt * 1e6 / len(reqs),
         f"{stats['skinny_dispatches']}skinny_disp_auto_routed",
         extra={"skinny_dispatches": stats["skinny_dispatches"],
                "requests": len(reqs),
                "dispatches_per_request": stats["dispatches_per_request"]})


def bench_bsr_serve() -> None:
    """Pruned-model serving lane: pools of same-geometry BSR weights
    (DLMC-style patterns on llama/qwen FFN geometries, budget-scaled with
    the aspect ratio preserved) served grouped vs per-request.  A pool of
    G same-sparsity members shares one bucketed group key, so the grouped
    path flushes as ONE batched dispatch (dispatches/request = 1/G); the
    mixed-sparsity DLMC grid row shows bucketing still amortizing across
    kept-block buckets.  Grouped results are bit-identical to the
    sequential path (asserted before timing)."""
    from repro.configs import get_config
    from repro.core.engine import SextansEngine
    from repro.data.matrices import (
        banded_pruned, block_random_pruned, dlmc_suite, magnitude_pruned)
    from repro.launch.serve import SpmmRequest, serve_spmm_requests
    from repro.sparse_api import Format, from_dense

    BLK = 16
    rng = np.random.default_rng(0)

    def scaled_ffn(arch: str, target: int = 128):
        cfg = get_config(arch)
        d = max(BLK, (target // BLK) * BLK)
        ff = max(BLK, int(round(cfg.d_ff / cfg.d_model * d / BLK)) * BLK)
        return d, ff

    def engine():
        return SextansEngine(tm=128, k0=128, chunk=8, impl="jnp")

    patterns = (magnitude_pruned, banded_pruned, block_random_pruned)
    for arch in ("llama3.2-1b", "qwen1.5-32b"):
        d, ff = scaled_ffn(arch)
        n = 32
        # G=16 pruned up-projections at one sparsity level: the exact
        # kept-block count is sparsity-determined, so all 16 share a
        # bucket and the grouped path is a single dispatch
        reqs = []
        for i in range(16):
            w = patterns[i % 3](d, ff, 0.90, block=(BLK, BLK), seed=i)
            a = from_dense(w.T, format=Format.BSR, block=(BLK, BLK))
            reqs.append(SpmmRequest(
                a=a, b=rng.standard_normal((d, n)).astype(np.float32)))

        outs_g, _ = serve_spmm_requests(reqs, engine(), batched=True)
        outs_s, _ = serve_spmm_requests(reqs, engine(), batched=False)
        bitexact = all(np.array_equal(x, y) for x, y in zip(outs_g, outs_s))
        assert bitexact, f"grouped BSR serving diverged ({arch})"

        for mode, kw in (("grouped", dict(batched=True)),
                         ("sequential", dict(batched=False))):
            best = None
            for _ in range(3):
                t0 = time.perf_counter()
                _, stats = serve_spmm_requests(reqs, engine(), **kw)
                dt = time.perf_counter() - t0
                if best is None or dt < best[0]:
                    best = (dt, stats)
            dt, stats = best
            us = dt * 1e6 / len(reqs)
            rps = len(reqs) / dt
            dpr = stats["dispatches_per_request"]
            tag = arch.split("-")[0].replace(".", "_")
            _row(f"bsr_serve_{mode}_{tag}", us,
                 f"{rps:.0f}req/s_{dpr:.3f}disp/req_"
                 f"bf{stats['batched_fraction']:.2f}"
                 + ("_bitexact_vs_sequential" if mode == "grouped" else ""),
                 extra={
                     "arch": arch,
                     "ffn_geometry": [d, ff],
                     "requests": len(reqs),
                     "requests_per_s": rps,
                     "dispatches_per_request": dpr,
                     "batched_fraction": stats["batched_fraction"],
                     "groups": stats["groups"],
                     "bit_identical": bitexact,
                 })

    # the full DLMC grid (3 patterns x 5 sparsities) on one geometry:
    # ragged kept-block counts spread over power-of-two buckets, grouped
    # dispatch count = number of occupied buckets, not requests
    d, ff = scaled_ffn("llama3.2-1b")
    reqs = []
    for e in dlmc_suite(d, ff, block=(BLK, BLK)):
        a = from_dense(e.weight.T, format=Format.BSR, block=(BLK, BLK))
        reqs.append(SpmmRequest(
            a=a, b=rng.standard_normal((d, 32)).astype(np.float32)))
    outs_g, _ = serve_spmm_requests(reqs, engine(), batched=True)
    outs_s, _ = serve_spmm_requests(reqs, engine(), batched=False)
    bitexact = all(np.array_equal(x, y) for x, y in zip(outs_g, outs_s))
    assert bitexact, "DLMC-grid grouped serving diverged"
    t0 = time.perf_counter()
    _, stats = serve_spmm_requests(reqs, engine(), batched=True)
    dt = time.perf_counter() - t0
    dpr = stats["dispatches_per_request"]
    _row("bsr_serve_dlmc_grid", dt * 1e6 / len(reqs),
         f"{len(reqs)}req_{stats['groups']}buckets_{dpr:.3f}disp/req_bitexact",
         extra={
             "requests": len(reqs),
             "requests_per_s": len(reqs) / dt,
             "dispatches_per_request": dpr,
             "batched_fraction": stats["batched_fraction"],
             "groups": stats["groups"],
             "bit_identical": bitexact,
         })


def bench_autotune() -> None:
    """Autotuned execution geometry + the persistent tuning/plan cache
    (``repro.sparse_api.autotune``): default-heuristic vs measured-best
    execution on a DLMC-style pruned pattern at the skinny-N boundary and
    on forced streaming (where the tuner picks the window-chunk/column-tile
    geometry the no-budget heuristic cannot), plus the cold-start story —
    ``autotune_first_build`` times this process's measure-mode plan build
    (DB+exec persistence make it cheap on the second run over the same
    ``SEXTANS_TUNE_DIR``), ``autotune_warm_rebuild`` rebuilds after
    ``clear_plan_cache()`` from persisted executables, and
    ``autotune_process2`` boots a fresh interpreter against the same tune
    dir and reports its time-to-first-dispatch (bit-identity of every
    tuned result is asserted/recorded throughout).  Uses
    ``SEXTANS_TUNE_DIR`` when set (the CI smoke sets it to diff a cold vs
    warm run), otherwise a fresh temp dir."""
    import hashlib
    import os
    import subprocess
    import sys
    import tempfile

    import jax
    import jax.numpy as jnp

    import repro
    import repro.sparse_api as sp
    from repro.core.engine import SextansEngine
    from repro.core.sparse import power_law_sparse
    from repro.data.matrices import magnitude_pruned
    from repro.launch.serve import SpmmRequest, serve_spmm_requests

    if not os.environ.get("SEXTANS_TUNE_DIR"):
        os.environ["SEXTANS_TUNE_DIR"] = tempfile.mkdtemp(
            prefix="sextans-tune-")
    tune_dir = os.environ["SEXTANS_TUNE_DIR"]

    rng = np.random.default_rng(0)
    # DLMC-style magnitude-pruned weight at the skinny-N boundary (N=8):
    # backend choice (tall kernel vs SpMV lane vs jnp) is live here
    w = magnitude_pruned(256, 512, 0.9, block=(16, 16), seed=1)
    A = sp.from_dense(np.asarray(w.T, np.float32), tm=128, k0=128, chunk=8,
                      bucket=True)
    nnz = A.nnz
    n = 8
    b = jnp.asarray(rng.standard_normal((A.shape[1], n)), jnp.float32)

    # -- cold-start: first measure-mode build in THIS process.  With a
    # pre-populated tune dir (CI run 2) the same call is a DB hit plus
    # persisted-executable loads — no measurement, no compile.
    ts0 = dict(sp.TUNE_STATS)
    ps0 = dict(sp.PLAN_STATS)
    t0 = time.perf_counter()
    P_tuned = sp.plan(A, n, autotune="measure")
    build_s = time.perf_counter() - t0
    _row("autotune_first_build", build_s * 1e6,
         f"{build_s:.3f}s_db_hits{sp.TUNE_STATS['db_hits'] - ts0['db_hits']}"
         f"_misses{sp.TUNE_STATS['db_misses'] - ts0['db_misses']}",
         extra={
             "build_s": build_s,
             "tune_db_hits": sp.TUNE_STATS["db_hits"] - ts0["db_hits"],
             "tune_db_misses": sp.TUNE_STATS["db_misses"] - ts0["db_misses"],
             "measured": sp.TUNE_STATS["measured"] - ts0["measured"],
             "exec_persist_hits": (sp.PLAN_STATS["exec_persist_hits"]
                                   - ps0["exec_persist_hits"]),
             "exec_persist_stores": (sp.PLAN_STATS["exec_persist_stores"]
                                     - ps0["exec_persist_stores"]),
             "tune_dir": tune_dir,
         })

    # -- default vs tuned throughput at the skinny boundary
    P_def = sp.plan(A, n)
    y_ref = np.asarray(P_def.run(b))
    y_tuned = np.asarray(P_tuned.run(b))
    bitexact = bool(np.array_equal(y_tuned, y_ref))
    assert bitexact, "tuned plan diverged from default resolution"
    us_d = _time_call(lambda: P_def.run(b).block_until_ready(), iters=10)
    us_t = _time_call(lambda: P_tuned.run(b).block_until_ready(), iters=10)
    mnnz_d = nnz / (us_d / 1e6) / 1e6
    mnnz_t = nnz / (us_t / 1e6) / 1e6
    _row("autotune_skinny_n8_default", us_d,
         f"{mnnz_d:.2f}Mnnz/s_{P_def.backend}",
         extra={"mnnz_per_s": mnnz_d, "backend": P_def.backend, "n": n})
    _row("autotune_skinny_n8_tuned", us_t,
         f"{mnnz_t:.2f}Mnnz/s_{P_tuned.backend}_"
         f"{us_d / us_t:.2f}x_vs_default_bitexact",
         extra={"mnnz_per_s": mnnz_t, "backend": P_tuned.backend, "n": n,
                "speedup_vs_default": us_d / us_t,
                "tuned": bool(P_tuned.tuned), "bit_identical": bitexact})

    # -- forced streaming: no budget -> the heuristic takes the finest
    # granularity (window_chunk=1); the tuner ranks the (wc, n_tile) grid
    # with the event-cycle model and measures the survivors
    big = power_law_sparse(1024, 8192, 6, seed=3)
    B = sp.from_sparse_matrix(big, tm=128, k0=128, chunk=8, bucket=True)
    bb = rng.standard_normal((8192, 16)).astype(np.float32)
    S_def = sp.plan(B, 16, backend="jnp", stream=True)
    S_tun = sp.plan(B, 16, backend="jnp", stream=True, autotune="measure")
    y_sd = np.asarray(S_def.run(bb))
    y_st = np.asarray(S_tun.run(bb))
    sbit = bool(np.array_equal(y_st, y_sd))
    assert sbit, "tuned streaming diverged from default streaming"
    us_sd = _time_call(lambda: jax.block_until_ready(S_def.run(bb)), iters=5)
    us_st = _time_call(lambda: jax.block_until_ready(S_tun.run(bb)), iters=5)
    mnnz_sd = big.nnz / (us_sd / 1e6) / 1e6
    mnnz_st = big.nnz / (us_st / 1e6) / 1e6
    _row("autotune_stream_default", us_sd,
         f"{mnnz_sd:.1f}Mnnz/s_wc{S_def.window_chunk}_"
         f"{S_def.window_dispatches}disp",
         extra={"mnnz_per_s": mnnz_sd, "window_chunk": S_def.window_chunk,
                "window_dispatches": S_def.window_dispatches})
    _row("autotune_stream_tuned", us_st,
         f"{mnnz_st:.1f}Mnnz/s_wc{S_tun.window_chunk}_"
         f"{S_tun.window_dispatches}disp_{us_sd / us_st:.2f}x_bitexact",
         extra={"mnnz_per_s": mnnz_st, "window_chunk": S_tun.window_chunk,
                "window_dispatches": S_tun.window_dispatches,
                "speedup_vs_default": us_sd / us_st,
                "tuned": bool(S_tun.tuned), "bit_identical": sbit})

    # -- warm rebuild: drop the in-process plan cache, rebuild in cached
    # mode — the decision comes from the DB, the executables from the
    # persisted .jaxexec files (no re-trace/re-compile)
    ps0 = dict(sp.PLAN_STATS)
    sp.clear_plan_cache()
    t0 = time.perf_counter()
    P_warm = sp.plan(A, n, autotune="cached")
    warm_s = time.perf_counter() - t0
    y_warm = np.asarray(P_warm.run(b))
    wbit = bool(np.array_equal(y_warm, y_ref))
    assert wbit, "warm-rebuilt plan diverged"
    _row("autotune_warm_rebuild", warm_s * 1e6,
         f"{warm_s:.3f}s_persist_hits"
         f"{sp.PLAN_STATS['exec_persist_hits'] - ps0['exec_persist_hits']}",
         extra={
             "build_s": warm_s,
             "warm_lt_cold": bool(warm_s < build_s),
             "exec_persist_hits": (sp.PLAN_STATS["exec_persist_hits"]
                                   - ps0["exec_persist_hits"]),
             "bit_identical": wbit,
         })

    # -- process 2: a FRESH interpreter against the same tune dir must
    # reach its first dispatch without measuring or re-tracing — the
    # cross-process cold-start kill.  The child rebuilds the same matrix
    # (deterministic seeds), plans in cached mode, and reports its
    # time-to-first-dispatch + a result digest the parent checks.
    child = (
        "import json, time, hashlib\n"
        "import numpy as np\n"
        "import jax.numpy as jnp\n"
        "import repro.sparse_api as sp\n"
        "from repro.data.matrices import magnitude_pruned\n"
        "w = magnitude_pruned(256, 512, 0.9, block=(16, 16), seed=1)\n"
        "A = sp.from_dense(np.asarray(w.T, np.float32), tm=128, k0=128,\n"
        "                  chunk=8, bucket=True)\n"
        "rng = np.random.default_rng(0)\n"
        "b = jnp.asarray(rng.standard_normal((A.shape[1], 8)), jnp.float32)\n"
        "t0 = time.perf_counter()\n"
        "P = sp.plan(A, 8, autotune='cached')\n"
        "y = np.asarray(P.run(b))\n"
        "dt = time.perf_counter() - t0\n"
        "print(json.dumps({'build_s': dt,\n"
        "                  'db_hits': sp.TUNE_STATS['db_hits'],\n"
        "                  'db_misses': sp.TUNE_STATS['db_misses'],\n"
        "                  'persist_hits': sp.PLAN_STATS['exec_persist_hits'],\n"
        "                  'sha': hashlib.sha256(y.tobytes()).hexdigest()}))\n"
    )
    env = dict(os.environ)
    src_root = os.path.dirname(os.path.dirname(
        os.path.abspath(repro.__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        [src_root] + [p for p in env.get("PYTHONPATH", "").split(os.pathsep)
                      if p])
    proc = subprocess.run([sys.executable, "-c", child], env=env,
                          capture_output=True, text=True, check=True)
    rep = json.loads(proc.stdout.strip().splitlines()[-1])
    p2bit = rep["sha"] == hashlib.sha256(y_ref.tobytes()).hexdigest()
    assert p2bit, "process-2 result diverged from process 1"
    _row("autotune_process2", rep["build_s"] * 1e6,
         f"{rep['build_s']:.3f}s_to_first_dispatch_db_hits{rep['db_hits']}"
         f"_persist{rep['persist_hits']}_bitexact",
         extra={
             "build_s": rep["build_s"],
             "tune_db_hits": rep["db_hits"],
             "tune_db_misses": rep["db_misses"],
             "exec_persist_hits": rep["persist_hits"],
             "bit_identical": p2bit,
         })

    # -- serving pool, default vs engine-tuned: the scheduler threads the
    # mode into every plan build; on a warm DB the tuned pool's plan
    # builds are pure lookups (tune_db_misses == 0 on the second run)
    reqs = [SpmmRequest(
        a=power_law_sparse(256 + 64 * (i % 2), 320, 5, seed=i),
        b=rng.standard_normal((320, 8)).astype(np.float32))
        for i in range(8)]

    def serve(autotune):
        eng = SextansEngine(tm=128, k0=128, chunk=8, impl="auto",
                            autotune=autotune)
        t0 = time.perf_counter()
        outs, stats = serve_spmm_requests(reqs, eng)
        return outs, stats, time.perf_counter() - t0

    outs_off, stats_off, dt_off = serve(None)
    serve("measure")                               # populate / verify DB
    outs_on, stats_on, dt_on = serve("measure")    # warm: all DB hits
    pbit = all(np.array_equal(x, y) for x, y in zip(outs_off, outs_on))
    assert pbit, "tuned serving pool diverged from default"
    _row("autotune_serve_pool_default", dt_off * 1e6 / len(reqs),
         f"{len(reqs) / dt_off:.0f}req/s",
         extra={"requests_per_s": len(reqs) / dt_off})
    _row("autotune_serve_pool_tuned", dt_on * 1e6 / len(reqs),
         f"{len(reqs) / dt_on:.0f}req/s_"
         f"{stats_on['tuned_dispatches']}tuned_"
         f"db{stats_on['tune_db_hits']}h/{stats_on['tune_db_misses']}m_"
         "bitexact",
         extra={
             "requests_per_s": len(reqs) / dt_on,
             "tuned_dispatches": stats_on["tuned_dispatches"],
             "tune_db_hits": stats_on["tune_db_hits"],
             "tune_db_misses": stats_on["tune_db_misses"],
             "plan_cache_hits": stats_on["plan_cache_hits"],
             "plan_cache_misses": stats_on["plan_cache_misses"],
             "plan_build_warm_s": stats_on["plan_build_warm_s"],
             "plan_build_cold_s": stats_on["plan_build_cold_s"],
             "bit_identical": pbit,
         })


def bench_validate() -> None:
    """Run the ``repro.analysis`` invariant validator over every packed
    artifact family the benchmarks dispatch (kernel/plan slabs, streaming
    slabs + a window slice, the serving bucket group, BSR, PE streams) and
    report the validation overhead per artifact — the cost of running with
    ``SEXTANS_CHECK=1``."""
    import repro.sparse_api as sp
    from repro.analysis.validate import validate
    from repro.core.hflex import pack_pe_streams
    from repro.core.partition import SextansParams
    from repro.core.sparse import power_law_sparse, to_dense

    kern = sp.from_sparse_matrix(power_law_sparse(512, 512, 6, seed=1),
                                 tm=128, k0=128, chunk=8, bucket=True)
    big = sp.from_sparse_matrix(power_law_sparse(1024, 8192, 6, seed=3),
                                tm=128, k0=128, chunk=8, bucket=True)
    group = sp.stack_hflex([
        sp.from_sparse_matrix(power_law_sparse(512, 512, 5, seed=i),
                              tm=128, k0=128, chunk=8, bucket=True)
        for i in range(4)])
    dense = to_dense(power_law_sparse(256, 256, 4, seed=7))
    bsr = sp.from_dense(np.asarray(dense, np.float32),
                        format=sp.Format.BSR, block=(64, 64))
    streams = pack_pe_streams(power_law_sparse(2000, 2000, 6, seed=2),
                              SextansParams(K0=512, P=16, D=10))
    artifacts = [
        ("kernel_slabs_512", kern),
        ("stream_slabs_1024x8192", big),
        ("stream_window_slice", big.windows(0, 4)),
        ("serve_bucket_group", group),
        ("bsr_weight_256", bsr),
        ("pe_streams_2000", streams),
    ]
    total_us = 0.0
    for name, art in artifacts:
        t0 = time.perf_counter()
        validate(art)
        us = (time.perf_counter() - t0) * 1e6
        total_us += us
        _row(f"validate_{name}", us, "invariants_ok")
    _row("validate_overhead_total", total_us,
         f"{len(artifacts)}artifacts_SEXTANS_CHECK_cost",
         extra={"artifacts": len(artifacts),
                "total_us": total_us,
                "per_artifact_us": total_us / len(artifacts)})


def compare_snapshots(old_path: str, new_path: str,
                      tolerance: float = 1.25) -> int:
    """Perf-regression diff between two ``--json`` snapshots.

    Joins rows by name and reports ``new_us / old_us`` per row: a ratio
    above ``tolerance`` is a REGRESSION, below ``1/tolerance`` an
    improvement, anything between is noise-tolerant ``ok``.  Rows present
    in only one snapshot are listed (dropped/added), not judged.  Returns
    the regression count (the CLI exits 2 when it is nonzero), so the
    BENCH_*.json trajectory can gate PRs instead of just accumulating.
    """
    with open(old_path) as f:
        old = json.load(f)
    with open(new_path) as f:
        new = json.load(f)
    old_rows = {r["name"]: r for r in old.get("rows", [])}
    new_rows = {r["name"]: r for r in new.get("rows", [])}
    regressions = 0
    print("name,old_us,new_us,ratio,verdict")
    for name, orow in old_rows.items():
        nrow = new_rows.get(name)
        if nrow is None:
            continue
        ou, nu = float(orow["us"]), float(nrow["us"])
        ratio = nu / ou if ou > 0 else float("inf")
        if ratio > tolerance:
            verdict = "REGRESSION"
            regressions += 1
        elif ratio < 1.0 / tolerance:
            verdict = "improved"
        else:
            verdict = "ok"
        print(f"{name},{ou:.1f},{nu:.1f},{ratio:.3f},{verdict}")
    dropped = sorted(set(old_rows) - set(new_rows))
    added = sorted(set(new_rows) - set(old_rows))
    if dropped:
        print(f"# dropped rows ({len(dropped)}): {','.join(dropped)}")
    if added:
        print(f"# added rows ({len(added)}): {','.join(added)}")
    print(f"# {regressions} regression(s) at tolerance {tolerance:.2f}x "
          f"over {len(set(old_rows) & set(new_rows))} shared rows")
    return regressions


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", choices=("small", "full"), default="small")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write rows as machine-readable JSON")
    ap.add_argument("--only", metavar="SUBSTR", default=None,
                    help="run only benchmark sections whose name contains "
                         "SUBSTR (e.g. --only serve)")
    ap.add_argument("--validate", action="store_true",
                    help="set SEXTANS_CHECK=1 for the whole run (every "
                         "benchmark input is invariant-checked at plan/"
                         "dispatch time) and append validate_* overhead "
                         "rows")
    ap.add_argument("--compare", nargs=2, metavar=("OLD", "NEW"),
                    default=None,
                    help="diff two --json snapshots instead of running "
                         "benchmarks; exits 2 if any shared row regressed "
                         "beyond --tolerance")
    ap.add_argument("--tolerance", type=float, default=1.25,
                    help="regression threshold for --compare (ratio "
                         "new/old; default 1.25)")
    args, _ = ap.parse_known_args()
    if args.compare:
        import sys

        regressions = compare_snapshots(args.compare[0], args.compare[1],
                                        tolerance=args.tolerance)
        sys.exit(2 if regressions else 0)
    if args.validate:
        import os

        os.environ["SEXTANS_CHECK"] = "1"
    sections = [
        ("table1", bench_table1),
        ("fig7", lambda: bench_fig7(args.budget)),
        ("fig9_fig10", lambda: bench_fig9_fig10(args.budget)),
        ("hub_split", lambda: bench_hub_split(args.budget)),
        ("kernels", bench_kernels),
        ("plan", bench_plan),
        ("scheduler", bench_scheduler),
        ("serve", bench_serve),
        ("slo", bench_slo),
        ("bsr_serve", bench_bsr_serve),
        ("stream", bench_stream),
        ("spmv", bench_spmv),
        ("autotune", bench_autotune),
    ]
    if args.validate:
        sections.append(("validate", bench_validate))
    print("name,us_per_call,derived")
    for name, fn in sections:
        if args.only and args.only not in name:
            continue
        fn()
    if args.json:
        payload = {
            "schema": 1,
            "budget": args.budget,
            "rows": ROWS,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {len(ROWS)} rows to {args.json}")


if __name__ == "__main__":
    main()
